"""Chunked streaming engine — bulk sliding-window aggregation (paper §8.2).

Turns the per-element SWAG scan into chunk-at-a-time bulk work, the
throughput counterpart of DABA's latency bound (cf. the authors' follow-up
on efficient bulk evictions/insertions, arXiv 2307.11210):

  * **intra-chunk** window outputs come from ONE dense sliding-window pass
    over the chunk — the Pallas VHGW kernel for scalar elementwise monoids,
    or a generic log-depth ``associative_scan`` VHGW for arbitrary pytree
    monoids;
  * **cross-chunk** boundaries are carried by a per-lane *tail* of suffix
    aggregates of the last ``window - 1`` elements, updated with one suffix
    scan per chunk (the dense analogue of DABA Lite's front list: output =
    Π_front ⊗ Π_back becomes ``y[i] = tail[i] ⊗ prefix[i]``).

Results equal the per-element ``BatchedSWAG.stream`` outputs exactly for
integer monoids and up to combine reassociation (allclose) for floats.

Layouts: streams are ``(T, B)``-leading like ``BatchedSWAG.stream``; the
Pallas kernels internally work on ``(B, T)``.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.monoids import Monoid
from repro.core.swag_base import (
    chunk_length,
    chunk_suffix_scan,
    tree_index,
)
from repro.kernels.ops_registry import combine_fn, op_for_monoid
from repro.kernels.sliding_window.kernel import sliding_window_pallas
from repro.kernels.suffix_scan.kernel import suffix_scan_pallas

PyTree = Any


# ---------------------------------------------------------------------------
# Generic (pytree-monoid) VHGW sliding window
# ---------------------------------------------------------------------------


def _axis1_prefix_scan(monoid: Monoid, blocks: PyTree) -> PyTree:
    return jax.lax.associative_scan(monoid.combine, blocks, axis=1)


def _axis1_suffix_scan(monoid: Monoid, blocks: PyTree) -> PyTree:
    flipped = jax.tree.map(lambda a: jnp.flip(a, 1), blocks)
    out = jax.lax.associative_scan(
        lambda a, b: monoid.combine(b, a), flipped, axis=1
    )
    return jax.tree.map(lambda a: jnp.flip(a, 1), out)


def tree_sliding_window(monoid: Monoid, lifted: PyTree, window: int) -> PyTree:
    """Front-truncated sliding-window fold along axis 0 of a lifted chunk.

    ``out[t] = lifted[max(0, t-window+1)] ⊗ … ⊗ lifted[t]`` — the VHGW
    (two-stacks-in-space) scheme of the Pallas kernel, expressed with
    ``associative_scan`` so it works for ANY pytree monoid: ~3 combines per
    element independent of ``window``, O(log window) depth.  Trailing axes
    (batch, element shape) ride along elementwise.
    """
    C = chunk_length(lifted)
    w = int(window)
    if w <= 1 or C == 0:
        return lifted
    ident = monoid.identity()
    nblk = -(-(C + w) // w)  # blocks of w covering [front pad w] + chunk
    total = nblk * w

    def pad(a, i):
        i = jnp.asarray(i, a.dtype)
        front = jnp.broadcast_to(i, (w,) + a.shape[1:])
        tail = jnp.broadcast_to(i, (total - w - C,) + a.shape[1:])
        return jnp.concatenate([front, a, tail], axis=0)

    padded = jax.tree.map(pad, lifted, ident)
    blocks = jax.tree.map(lambda a: a.reshape((nblk, w) + a.shape[1:]), padded)
    p = _axis1_prefix_scan(monoid, blocks)   # P[j, i] = fold(block_j[0..i])
    s = _axis1_suffix_scan(monoid, blocks)   # S[j, i] = fold(block_j[i..w-1])
    pf = jax.tree.map(lambda a: a.reshape((total,) + a.shape[2:]), p)
    sf = jax.tree.map(lambda a: a.reshape((total,) + a.shape[2:]), s)

    # Window ending at chunk position t covers padded [t+1 .. t+w]:
    # left fragment S[t+1] (identity when t+1 sits on a block boundary —
    # the window is then exactly one block's prefix), right fragment P[t+w].
    idx = jnp.arange(C, dtype=jnp.int32)
    on_boundary = ((idx + 1) % w) == 0
    left = jax.tree.map(
        lambda a, i: jnp.where(
            on_boundary.reshape((C,) + (1,) * (a.ndim - 1)),
            jnp.asarray(i, a.dtype),
            a[idx + 1],
        ),
        sf,
        ident,
    )
    right = jax.tree.map(lambda a: a[idx + w], pf)
    return monoid.combine(left, right)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class ChunkedStream:
    """Chunk-at-a-time count-based sliding-window aggregation over (T, B).

    Usage::

        eng = ChunkedStream(monoid, window=1024, chunk=1024)
        carry = eng.init_carry(batch)
        carry, ys = eng.process_chunk(carry, xs_chunk)   # (C, B) in/out
        ...                                              # or, whole stream:
        ys = eng.stream(xs)                              # (T, B) -> (T, B)

    ``ys[t]`` is the *aggregate* (pre-``lower``) of the last ``window``
    elements ending at t, front-truncated during fill — element-for-element
    what ``BatchedSWAG.stream`` emits, computed ~3 combines/element in bulk
    instead of O(1)-per-element sequential dispatch.

    When the monoid maps onto a registry op (sum/min/max/logsumexp/..., see
    :mod:`repro.kernels.ops_registry`) the intra-chunk passes run on the
    Pallas ``sliding_window``/``suffix_scan`` kernels; any other monoid uses
    the generic ``associative_scan`` path.  The carry is a per-lane tail of
    ``window - 1`` suffix aggregates — the engine never stores raw history.
    """

    def __init__(
        self,
        monoid: Monoid,
        window: int,
        chunk: Optional[int] = None,
        *,
        use_kernel: bool = True,
        interpret: Optional[bool] = None,
        block_b: int = 8,
    ):
        self.monoid = monoid
        self.window = int(window)
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.chunk = int(chunk) if chunk is not None else max(self.window, 256)
        self.op = op_for_monoid(monoid) if use_kernel else None
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = interpret
        self.block_b = block_b
        self._jitted_pc = jax.jit(self._process_chunk_impl)

    # -- carry ------------------------------------------------------------

    def init_carry(self, batch: int) -> PyTree:
        """Tail of suffix aggregates of the last window-1 elements (per lane),
        identity-filled: missing history combines away exactly (= the
        front-truncated fill semantics)."""
        h = self.window - 1
        ident = self.monoid.identity()
        if self.op is not None:
            ident = jnp.asarray(ident)
            return jnp.full((batch, h), ident, ident.dtype)
        return jax.tree.map(
            lambda i: jnp.broadcast_to(i, (h, batch) + i.shape).copy(), ident
        )

    # -- one chunk ---------------------------------------------------------

    def process_chunk(self, carry: PyTree, xs: PyTree):
        """Consume a (C, B) chunk of raw inputs; returns (carry, (C, B) aggs)."""
        return self._jitted_pc(carry, xs)

    def _process_chunk_impl(self, carry, xs):
        if self.op is not None:
            return self._chunk_kernel(carry, xs)
        return self._chunk_generic(carry, xs)

    def _chunk_kernel(self, tail, xs):
        m = self.monoid
        lifted = jax.vmap(jax.vmap(m.lift))(xs)  # (C, B) scalar Agg
        if lifted.ndim != 2:
            raise ValueError(
                f"kernel path needs scalar aggregates, got shape {lifted.shape}"
            )
        x = lifted.T  # (B, C) for the kernels
        C = x.shape[1]
        w, h = self.window, min(self.window - 1, x.shape[1])
        comb = combine_fn(self.op)
        y = sliding_window_pallas(
            x, window=w, op=self.op, block_b=self.block_b, interpret=self.interpret
        )
        if h > 0:
            y = y.at[:, :h].set(comb(tail[:, :h], y[:, :h]))
        if w > 1:
            ss = suffix_scan_pallas(
                x, op=self.op, block_b=self.block_b, interpret=self.interpret
            )
            if C >= w - 1:
                tail = ss[:, C - (w - 1):]
            else:
                # shift the old tail down by C and absorb the chunk total
                tail = jnp.concatenate([comb(tail[:, C:], ss[:, :1]), ss], axis=1)
        return tail, y.T

    def _chunk_generic(self, tail, xs):
        m = self.monoid
        lifted = jax.vmap(jax.vmap(m.lift))(xs)  # (C, B, ...) Agg pytree
        C = chunk_length(lifted)
        w, h = self.window, min(self.window - 1, chunk_length(lifted))
        y = tree_sliding_window(m, lifted, w)
        if h > 0:
            fixed = m.combine(
                jax.tree.map(lambda a: a[:h], tail),
                jax.tree.map(lambda a: a[:h], y),
            )
            y = jax.tree.map(lambda a, f: a.at[:h].set(f), y, fixed)
        if w > 1:
            ss = chunk_suffix_scan(m, lifted)
            if C >= w - 1:
                tail = jax.tree.map(lambda a: a[C - (w - 1):], ss)
            else:
                total = tree_index(ss, 0)
                shifted = jax.vmap(m.combine, in_axes=(0, None))(
                    jax.tree.map(lambda a: a[C:], tail), total
                )
                tail = jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b], axis=0), shifted, ss
                )
        return tail, y

    # -- whole stream ------------------------------------------------------

    def stream(self, xs: PyTree) -> PyTree:
        """Aggregate a whole (T, B) stream chunk-by-chunk; returns (T, B) aggs."""
        T = chunk_length(xs)
        batch = jax.tree.leaves(xs)[0].shape[1]
        if T == 0:  # match the per-element scan: well-formed empty (0, B) aggs
            return jax.vmap(jax.vmap(self.monoid.lift))(xs)
        carry = self.init_carry(batch)
        ys = []
        for lo in range(0, T, self.chunk):
            piece = jax.tree.map(lambda a: a[lo: lo + self.chunk], xs)
            carry, y = self.process_chunk(carry, piece)
            ys.append(y)
        return jax.tree.map(lambda *parts: jnp.concatenate(parts, axis=0), *ys)
